"""Search fast-path acceptance: memoization correctness + instrumentation.

The wall-clock speedup itself is reported as ``search.perf.*`` BENCH
rows (benchmarks/dse.py) — never asserted here, where a noisy CI box
would make it flake.  What IS asserted is the half that must never
regress silently:

  * dedup-on and dedup-off (brute-force) ``auto_schedule`` produce
    BIT-IDENTICAL Schedule documents on every registered workload —
    the memo tables, pruned enumeration, and hoisted DP are exact;
  * the memo actually bites: hit rate > 0.5 on MobileViT-S;
  * layer/HW signatures capture content and nothing else (cosmetic
    renames keep cache keys, dim changes break them);
  * placement-aware headline costing is bit-neutral on the paper's
    3-level design and splits the rows on a deeper hierarchy;
  * the process-pool DSE fan-out returns the same points as serial.
"""
import dataclasses
import subprocess
import sys

import pytest

from repro.core.costmodel import HWSpec, cost_network_scheduled
from repro.core.memory import split_sram_hierarchy
from repro.core.workload import MAC_OPS, Layer
from repro.search import (WORKLOADS, auto_schedule, evaluate_schedule,
                          get_workload, schedule_key, sweep_memory)
from repro.search import mapper, partition
from repro.search.memo import SearchMemo
from repro.search.perf import PerfRecorder

HW = HWSpec()
KB = 1024


# ---------------------------------------------------------------------------
# dedup-on == dedup-off, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", WORKLOADS)
def test_dedup_modes_bit_identical(name):
    """The acceptance property: for every registered workload the
    memoized fast path and the brute-force reference produce the same
    Schedule JSON — same key, same decisions, same costs (floats
    compared exactly, not approximately)."""
    wl = get_workload(name)
    fast = auto_schedule(wl, HW, workload=name, dedup=True)
    brute = auto_schedule(wl, HW, workload=name, dedup=False)
    assert fast.key == brute.key
    assert fast.cost == brute.cost          # exact float equality
    assert dataclasses.asdict(fast) == dataclasses.asdict(brute)


def test_dedup_modes_bit_identical_on_deep_hierarchy():
    """Same property on a 4-level hierarchy, where placements and
    residence levels actually differ from the paper design."""
    hw = HWSpec(hierarchy=split_sram_hierarchy())
    wl = get_workload("edgenext-s")
    fast = auto_schedule(wl, hw, dedup=True)
    brute = auto_schedule(wl, hw, dedup=False)
    assert dataclasses.asdict(fast) == dataclasses.asdict(brute)


def test_dedup_modes_bit_identical_pow2_and_fixed():
    """Ablation modes ride the same fast path: tile_mode and the
    fixed-wiring restriction must stay bit-exact too."""
    wl = get_workload("edgenext-reduced")
    for kw in ({"tile_mode": "pow2"}, {"tile_mode": "legacy"},
               {"reconfigurable": False}):
        fast = auto_schedule(wl, HW, dedup=True, **kw)
        brute = auto_schedule(wl, HW, dedup=False, **kw)
        assert dataclasses.asdict(fast) == dataclasses.asdict(brute), kw


def test_memo_hit_rate_on_mobilevit():
    """MobileViT-S registers 156 layers but far fewer unique shapes —
    the memo must catch more than half of all lookups."""
    perf = PerfRecorder()
    auto_schedule(get_workload("mobilevit-s"), HW,
                  workload="mobilevit-s", perf=perf)
    assert perf.hit_rate() > 0.5, perf.counters
    # and the per-table counters all saw traffic
    for table in ("spatial", "temporal", "group_tile"):
        hits = perf.counters.get(f"memo.{table}.hit", 0)
        assert hits > 0, (table, perf.counters)


def test_best_temporal_fast_equals_brute_per_layer():
    """Mapper-level equivalence, both pixelwise-constrained and free,
    including the TemporalChoice internals (placement, level bytes,
    exact energy)."""
    wl = get_workload("edgenext-s")
    memo = SearchMemo()
    seen = set()
    for l in wl:
        if l.op not in MAC_OPS or l.signature in seen:
            continue
        seen.add(l.signature)
        for rp in (False, True):
            fast = mapper.best_temporal(l, HW, require_pixelwise=rp,
                                        memo=memo)
            brute = mapper.best_temporal(l, HW, require_pixelwise=rp,
                                         brute=True)
            assert fast == brute, (l.name, rp)


def test_partition_fast_equals_brute():
    """Partitioner-level equivalence: the hoisted/memoized DP and the
    original per-span derivation return identical groups, edges, and
    total cost."""
    wl = get_workload("mobilevit-s")
    cyc = {l.name: mapper.best_mapping(l, HW.rows, HW.cols).cycles
           for l in wl if l.op in MAC_OPS}
    fast = partition.partition_chain(wl, cyc, HW, memo=SearchMemo())
    brute = partition.partition_chain(wl, cyc, HW)
    assert fast.groups == brute.groups
    assert fast.edges == brute.edges
    assert fast.cost_pj == brute.cost_pj    # exact float equality


# ---------------------------------------------------------------------------
# signatures + cache keys
# ---------------------------------------------------------------------------


def test_layer_signature_ignores_name_and_annotations():
    a = Layer("a", "pwconv", k=64, c=32, ox=196, ibn_role="expand",
              ibn_id=7)
    b = Layer("totally.different", "pwconv", k=64, c=32, ox=196)
    c = Layer("a", "pwconv", k=64, c=33, ox=196)
    d = Layer("a", "matmul", k=64, c=32, ox=196)
    assert a.signature == b.signature
    assert a.signature != c.signature
    assert a.signature != d.signature


def test_schedule_key_stable_across_cosmetic_renames():
    """The cache-key satellite: renaming layers (or dropping the ibn
    annotations) keeps the key; changing any dim or the HW breaks it."""
    wl = get_workload("edgenext-reduced")
    renamed = [dataclasses.replace(l, name=f"layer{i}", ibn_role=None,
                                   ibn_id=-1)
               for i, l in enumerate(wl)]
    assert schedule_key(wl, HW) == schedule_key(renamed, HW)
    bumped = list(wl)
    bumped[0] = dataclasses.replace(wl[0], k=wl[0].k + 1)
    assert schedule_key(bumped, HW) != schedule_key(wl, HW)
    hw2 = dataclasses.replace(HW, sram_bytes=HW.sram_bytes * 2)
    assert schedule_key(wl, hw2) != schedule_key(wl, HW)
    assert schedule_key(wl, HW, "pow2") != schedule_key(wl, HW)


def test_cached_replay_remaps_renamed_layers(tmp_path):
    """A rename-stable cache key must deliver a *usable* schedule after
    the rename: the replayed artifact's name-keyed decisions are
    remapped positionally onto the new names, and evaluating it equals
    evaluating a fresh search on the renamed chain."""
    from repro.search import cached_search
    wl = get_workload("edgenext-reduced")
    s1 = cached_search(wl, HW, workload="edgenext-reduced",
                       cache_dir=tmp_path)
    renamed = [dataclasses.replace(l, name=f"renamed{i}")
               for i, l in enumerate(wl)]
    s2 = cached_search(renamed, HW, workload="edgenext-reduced",
                       cache_dir=tmp_path)
    assert len(list(tmp_path.glob("*.json"))) == 1   # same key: a hit
    assert s2.key == s1.key
    assert set(s2.mappings) == {l.name for l in renamed
                                if l.op in MAC_OPS}
    fresh = auto_schedule(renamed, HW, workload="edgenext-reduced")
    assert s2.mappings == fresh.mappings
    assert s2.groups == fresh.groups
    nc = evaluate_schedule(renamed, s2, HW)
    assert nc.energy_j == fresh.cost["energy_j"]
    assert nc.latency_s == fresh.cost["latency_s"]


def test_guards_reject_contradictory_modes():
    wl = get_workload("edgenext-reduced")
    with pytest.raises(ValueError):
        auto_schedule(wl, HW, dedup=False, memo=SearchMemo())
    with pytest.raises(ValueError):
        sweep_memory(wl, HW, sizings={"rf": (16 * KB, 32 * KB)},
                     memo=SearchMemo(), parallel=2)


def test_signature_field_lists_track_the_dataclasses():
    """Canary: the content signatures hand-enumerate the fields they
    hash (``_layer_signature``, ``_hw_signature``, the hierarchy
    signatures, and ``auto_schedule``'s hw_doc).  Adding a field to any
    of these dataclasses MUST update those enumerations (and bump
    SEARCH_VERSION) or two differing specs would silently share memo
    entries and cache keys — this assert is the tripwire."""
    from repro.core.memory import MemoryLevel
    assert {f.name for f in dataclasses.fields(Layer)} == {
        "name", "op", "b", "k", "c", "ox", "oy", "fx", "fy", "bits",
        "ibn_role", "ibn_id"}, \
        "Layer grew a field: update workload._layer_signature"
    assert {f.name for f in dataclasses.fields(HWSpec)} == {
        "rows", "cols", "clock_hz", "bits", "e_mac", "static_mw",
        "hierarchy"}, \
        "HWSpec grew a field: update costmodel._hw_signature + " \
        "auto_schedule's hw_doc"
    assert {f.name for f in dataclasses.fields(MemoryLevel)} == {
        "name", "bytes", "pj_per_byte", "bus_bytes_per_cycle",
        "serves", "partitions"}, \
        "MemoryLevel grew a field: update MemoryHierarchy.signature/" \
        "cap_signature"


def test_hw_signature_content_addressed():
    assert HWSpec().signature == HW.signature
    assert HWSpec(rows=8).signature != HW.signature
    assert HWSpec(e_sram_byte=2.0).signature != HW.signature
    assert HWSpec(hierarchy=split_sram_hierarchy()).signature \
        != HW.signature
    h = HW.hierarchy
    assert h.cap_signature == \
        HW.hierarchy.resized("sram", pj_per_byte=9.9).cap_signature
    assert h.signature != \
        HW.hierarchy.resized("sram", pj_per_byte=9.9).signature
    assert h.cap_signature != \
        HW.hierarchy.resized("sram", bytes=256 * KB).cap_signature


# ---------------------------------------------------------------------------
# placement-aware headline costing (ROADMAP satellite)
# ---------------------------------------------------------------------------


SCHED = auto_schedule(get_workload("edgenext-s"), HW,
                      workload="edgenext-s")


def _traffic_rows(nc):
    return [lc.traffic for lc in nc.layers]


def test_placement_costing_neutral_on_paper_design():
    """On the 3-level paper hierarchy every placed fill resolves to the
    SRAM, so the placement-aware rows reproduce the lumped
    stream-level accounting bit-exactly (the golden EdgeNeXt snapshot
    changed only its version field in this PR)."""
    wl = get_workload("edgenext-s")
    mappings = {k: tuple(v) for k, v in SCHED.mappings.items()}
    with_pl = cost_network_scheduled(
        wl, HW, mappings=mappings,
        fused_nonlinear=set(SCHED.fused_nonlinear),
        edges=SCHED.spill_edge_list(), placements=SCHED.placements)
    lumped = cost_network_scheduled(
        wl, HW, mappings=mappings,
        fused_nonlinear=set(SCHED.fused_nonlinear),
        edges=SCHED.spill_edge_list())
    assert _traffic_rows(with_pl) == _traffic_rows(lumped)
    assert with_pl.energy_j == lumped.energy_j


def test_placement_costing_splits_rows_on_deep_hierarchy():
    """On the 4-level split-SRAM design, weights whose tiles exceed the
    small L1 are placed (and now also *charged*) at the L2 — the rows
    follow the mapper's placements instead of lumping everything at the
    stream level."""
    hw = HWSpec(hierarchy=split_sram_hierarchy())
    wl = get_workload("edgenext-s")
    sched = auto_schedule(wl, hw, workload="edgenext-s")
    assert any(p["weight"] == "l2" for p in sched.placements.values())
    nc = evaluate_schedule(wl, sched, hw)
    tr = nc.traffic_bytes()
    assert tr["l2"] > 0
    mappings = {k: tuple(v) for k, v in sched.mappings.items()}
    lumped = cost_network_scheduled(
        wl, hw, mappings=mappings,
        fused_nonlinear=set(sched.fused_nonlinear),
        edges=sched.spill_edge_list())
    assert tr["l1"] < lumped.traffic_bytes()["l1"]
    # total operand bytes conserved — only the level attribution moved
    assert sum(tr.values()) == sum(lumped.traffic_bytes().values())


# ---------------------------------------------------------------------------
# FastViT workload (satellite)
# ---------------------------------------------------------------------------


def test_fastvit_workload_registered():
    from repro.core.workload import ibn_groups, total_macs
    wl = get_workload("fastvit-s")
    g = total_macs(wl) / 1e9
    assert 1.0 < g < 2.0, g                 # SA12-like scale
    assert len(ibn_groups(wl)) == sum((2, 2, 6, 2))   # one FFN per block
    wl4 = get_workload("fastvit-s-b4")
    assert total_macs(wl4) == 4 * total_macs(wl)
    assert {"fastvit-s", "fastvit-s-b4"} <= set(WORKLOADS)
    # repeat-heavy by construction: far fewer unique shapes than layers
    assert len({l.signature for l in wl}) < len(wl) / 2
    from repro.core.schedule import evaluate_stack
    sched = auto_schedule(wl, HW, workload="fastvit-s")
    assert sched.cost["edp"] <= evaluate_stack(wl, HW)[-1].edp * (1 + 1e-9)


# ---------------------------------------------------------------------------
# incremental DSE + process-pool fan-out
# ---------------------------------------------------------------------------


def test_sweep_memory_dedup_matches_brute():
    """A sweep-wide shared memo must not leak decisions across variants:
    every point equals its from-scratch counterpart."""
    wl = get_workload("edgenext-reduced")
    sizings = {"rf": (16 * KB, 32 * KB), "sram": (256 * KB, 512 * KB)}
    fast = sweep_memory(wl, HW, sizings=sizings, dedup=True)
    brute = sweep_memory(wl, HW, sizings=sizings, dedup=False)
    assert len(fast) == len(brute) == 4
    for a, b in zip(fast, brute):
        assert a.mem == b.mem
        assert dataclasses.asdict(a.schedule) == \
            dataclasses.asdict(b.schedule)


def test_sweep_memory_parallel_matches_serial():
    """Process-pool fan-out returns the same points as serial AND
    merges the workers' PerfRecorder tables back (the --profile --jobs
    fix): phase wall times and memo counters must be non-zero, not the
    silently-empty recorder the pool used to leave behind."""
    wl = get_workload("edgenext-reduced")
    sizings = {"rf": (16 * KB, 32 * KB)}
    serial = sweep_memory(wl, HW, sizings=sizings)
    perf = PerfRecorder()
    par = sweep_memory(wl, HW, sizings=sizings, parallel=2, perf=perf)
    assert [p.label for p in par] == [p.label for p in serial]
    for a, b in zip(par, serial):
        assert dataclasses.asdict(a.schedule) == \
            dataclasses.asdict(b.schedule)
    # merged worker profiles: every search phase accumulated real time
    for phase in ("spatial", "partition", "temporal", "evaluate"):
        assert perf.phase_s.get(phase, 0.0) > 0.0, (phase, perf.phase_s)
    hits = sum(v for k, v in perf.counters.items() if k.endswith(".hit"))
    miss = sum(v for k, v in perf.counters.items() if k.endswith(".miss"))
    assert hits + miss > 0 and perf.hit_rate() > 0.0
    assert perf.rows("perf")               # renders as BENCH/CLI rows


def test_shared_memo_accumulates_across_variants():
    """Spatial mappings are hierarchy-independent: the second variant
    of a memory sweep must hit the shared spatial table, and group
    tiles shared across equal residence capacities must hit too."""
    wl = get_workload("edgenext-reduced")
    perf = PerfRecorder()
    sweep_memory(wl, HW, sizings={"sram": (256 * KB, 512 * KB)},
                 perf=perf)
    c = perf.counters
    assert c["memo.spatial.hit"] > c["memo.spatial.miss"]
    # sram-only sweep keeps the rf residence budget: per-capacity group
    # tiles from variant 1 serve variant 2 entirely
    assert c["memo.group_tile.hit"] > c["memo.group_tile.miss"]


def test_caller_supplied_memo_reports_to_caller_perf():
    """Passing BOTH memo= and perf= (the documented cross-sweep
    sharing) must land the memo hit/miss counters on the caller's
    recorder, not the memo's private default one."""
    wl = get_workload("edgenext-reduced")
    memo, perf = SearchMemo(), PerfRecorder()
    sweep_memory(wl, HW, sizings={"sram": (256 * KB, 512 * KB)},
                 memo=memo, perf=perf)
    assert perf.counters and perf.hit_rate() > 0.0, perf.counters
    assert perf.counters.get("memo.spatial.hit", 0) > 0


# ---------------------------------------------------------------------------
# instrumentation + CLI
# ---------------------------------------------------------------------------


def test_perf_recorder_rows():
    p = PerfRecorder()
    with p.phase("a"):
        pass
    p.count("memo.spatial.hit", 3)
    p.count("memo.spatial.miss")
    assert p.hit_rate() == pytest.approx(0.75)
    assert p.hit_rate("spatial") == pytest.approx(0.75)
    names = [r[0] for r in p.rows("x")]
    assert "x.phase.a_ms" in names
    assert "x.memo.spatial.hit_rate" in names
    assert "x.total_ms" in names


def test_cli_profile_smoke(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.search", "--workload",
         "edgenext-reduced", "--profile"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "perf.auto.speedup," in r.stdout
    assert "perf.memo.hit_rate," in r.stdout
    assert "cost.edp" in r.stdout
