"""Serving layer: batch as a mapspace dim, the warm artifact store,
the arrival-rate batching policy, cache atomicity under crashes and
concurrent writers, and the data-parallel fan-out."""
import dataclasses
import json
import signal
import subprocess
import sys
import textwrap
import time

import pytest

from repro import obs
from repro.core.costmodel import HWSpec
from repro.core.workload import (Layer, edgenext_serving_workload,
                                 fastvit_serving_workload,
                                 mobilevit_serving_workload, vit_workload,
                                 with_batch)
from repro.search import get_workload, parse_workload
from repro.search.cache import (SEARCH_VERSION, _remap_layer_names,
                                cached_search)
from repro.serve import (BatchPoint, ServeStore, canonical_name,
                         distinct_batches, pick_batch, rate_table)

# JAX_PLATFORMS=cpu: the image ships libtpu; without the override a
# child process burns 60+s probing a TPU backend that does not exist.
ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}


# ---------------------------------------------------------------------------
# batch as a first-class mapspace dim
# ---------------------------------------------------------------------------


def test_with_batch_scales_b_only():
    wl = get_workload("edgenext-reduced")
    b4 = with_batch(wl, 4)
    assert [l.name for l in b4] == [l.name for l in wl]
    for a, b in zip(wl, b4):
        assert b.b == 4 * a.b
        assert dataclasses.replace(b, b=a.b) == a


def test_with_batch_identity_and_validation():
    wl = get_workload("edgenext-reduced")
    same = with_batch(wl, 1)
    assert same == wl and same is not wl
    with pytest.raises(ValueError):
        with_batch(wl, 0)


def test_with_batch_matches_serving_builders():
    """The generalized transform reproduces every hand-written -b4
    serving builder layer-for-layer (names included)."""
    for name, builder in (("edgenext-s", edgenext_serving_workload),
                          ("fastvit-s", fastvit_serving_workload),
                          ("mobilevit-s", mobilevit_serving_workload)):
        assert with_batch(get_workload(name), 4) == builder(batch=4)
        assert get_workload(f"{name}-b4") == builder(batch=4)


def test_registry_resolves_any_batch_suffix():
    assert get_workload("vit-tiny-b16") == with_batch(vit_workload(), 16)
    assert parse_workload("edgenext-s-b64") == ("edgenext-s", 64)
    assert parse_workload("edgenext-s") == ("edgenext-s", 1)
    # 'b0' is an architecture suffix, not a batch level
    assert parse_workload("efficientvit-b0") == ("efficientvit-b0", 1)
    with pytest.raises(KeyError):
        get_workload("no-such-arch-b4")


def test_canonical_name_composes_batches():
    assert canonical_name("edgenext-s", 4) == "edgenext-s-b4"
    assert canonical_name("edgenext-s", 1) == "edgenext-s"
    assert canonical_name("edgenext-s-b4", 4) == "edgenext-s-b16"


# ---------------------------------------------------------------------------
# cache correctness: atomic writes, duplicate names, concurrent writers
# ---------------------------------------------------------------------------

_TINY = [Layer("l0", "pwconv", k=8, c=8, ox=4, oy=4),
         Layer("l1", "dwconv", c=8, ox=4, oy=4, fx=3, fy=3)]


def test_save_schedule_atomic_under_kill(tmp_path):
    """SIGKILL a writer loop at arbitrary instants: the artifact is
    always either absent or complete valid JSON, and the temp files a
    crash can leave behind never match the ``*.json`` loader glob."""
    art = tmp_path / "wl-abc.json"
    child = textwrap.dedent(f"""
        import dataclasses, sys
        from pathlib import Path
        from repro.search.cache import save_schedule

        @dataclasses.dataclass
        class Doc:
            version: int
            payload: str

        doc = Doc(version=1, payload="x" * 500_000)
        path = Path({str(art)!r})
        print("ready", flush=True)
        while True:
            save_schedule(doc, path)
    """)
    for delay in (0.0, 0.01, 0.05):
        p = subprocess.Popen([sys.executable, "-c", child], env=ENV,
                             cwd="/root/repo", stdout=subprocess.PIPE,
                             text=True)
        try:
            assert p.stdout.readline().strip() == "ready"
            time.sleep(delay)
        finally:
            p.send_signal(signal.SIGKILL)
            p.wait(timeout=30)
        if art.exists():
            doc = json.loads(art.read_text())      # complete, parseable
            assert len(doc["payload"]) == 500_000
        leftovers = list(tmp_path.glob("*.json"))
        assert leftovers in ([], [art]), leftovers


def test_remap_rejects_duplicate_layer_names(tmp_path):
    """Regression: an artifact whose chain holds two identically named
    layers cannot be positionally remapped onto distinct request names
    — ``dict(zip())`` used to keep the last pairing silently.  The
    remap must reject it; ``cached_search`` then treats the artifact as
    corrupt and re-searches."""
    hw = HWSpec()
    twin = [dataclasses.replace(_TINY[0], name="n0"),
            dataclasses.replace(_TINY[0], name="n1")]    # equal signatures
    sched = cached_search(twin, hw, workload="twin", cache_dir=tmp_path)

    # unit level: duplicate old names pairing with two new names, and
    # two old names collapsing onto one new name, both reject
    dup = dataclasses.replace(
        sched, groups=tuple(("n0",) for _ in sched.groups))
    assert _remap_layer_names(dup, twin) is None
    collapse = [dataclasses.replace(l, name="same") for l in twin]
    assert _remap_layer_names(sched, collapse) is None

    # integration: corrupt the stored artifact so both chain positions
    # claim the same name, then replay — must re-search, not mis-remap
    art = next(tmp_path.glob("twin-*.json"))
    art.write_text(art.read_text().replace('"n1"', '"n0"'))
    with obs.tracing() as tr:
        again = cached_search(twin, hw, workload="twin",
                              cache_dir=tmp_path)
    assert tr.counters.get("cache.corrupt") == 1
    assert tr.counters.get("cache.miss") == 1
    assert tr.counters.get("cache.store") == 1
    assert not tr.counters.get("cache.hit")
    assert dataclasses.asdict(again) == dataclasses.asdict(sched)


# Concurrent-writer atomicity (exactly one store per key across racing
# processes, no lost artifacts, no double takeover) is covered by the
# exhaustive interleaving explorer + deterministic flock tests in
# tests/test_check_races.py — strictly stronger than the 4-process
# wall-clock race this file used to run.


# ---------------------------------------------------------------------------
# the warm store
# ---------------------------------------------------------------------------


def test_store_warm_then_hit_counters(tmp_path):
    store = ServeStore(tmp_path, HWSpec())
    with obs.tracing() as tr:
        rep = store.warm(["edgenext-reduced"], batches=(1, 2))
    assert rep.entries == ("edgenext-reduced", "edgenext-reduced-b2")
    assert rep.searched == 2 and len(rep.keys) == 2
    assert tr.counters.get("cache.miss") == 2
    assert tr.counters.get("cache.store") == 2
    assert len(store) == 2

    # warm store: every lookup is a memory hit, never the DP
    with obs.tracing() as tr:
        s1 = store.lookup("edgenext-reduced", 1)
        s2 = store.lookup("edgenext-reduced", 2)
    assert tr.counters.get("cache.hit") == 2
    assert tr.counters.get("serve.store.mem_hit") == 2
    assert not tr.counters.get("cache.miss")
    assert s2.cost["latency_s"] > s1.cost["latency_s"]

    # second warm over a superset: only the new grid point searches
    with obs.tracing() as tr:
        rep2 = store.warm(["edgenext-reduced"], batches=(1, 2, 4))
    assert rep2.searched == 1 and len(rep2.entries) == 3


def test_store_disk_tier_and_version_reject(tmp_path):
    hot = ServeStore(tmp_path, HWSpec())
    hot.warm(["edgenext-reduced"], batches=(1,))
    # a fresh store (new process analogue) replays from disk: cache.hit
    # without the memory layer
    cold = ServeStore(tmp_path, HWSpec())
    with obs.tracing() as tr:
        cold.lookup("edgenext-reduced", 1)
    assert tr.counters.get("cache.hit") == 1
    assert not tr.counters.get("serve.store.mem_hit")
    assert not tr.counters.get("cache.miss")
    # stale engine version: rejected, re-searched, re-stored
    art = next(tmp_path.glob("edgenext-reduced-*.json"))
    doc = json.loads(art.read_text())
    doc["version"] = SEARCH_VERSION - 1
    art.write_text(json.dumps(doc))
    stale = ServeStore(tmp_path, HWSpec())
    with obs.tracing() as tr:
        stale.lookup("edgenext-reduced", 1)
    assert tr.counters.get("cache.version_reject") == 1
    assert tr.counters.get("cache.miss") == 1
    assert tr.counters.get("cache.store") == 1


def test_store_warm_process_pool_folds_counters(tmp_path):
    store = ServeStore(tmp_path, HWSpec())
    with obs.tracing() as tr:
        rep = store.warm(["edgenext-reduced"], batches=(1, 2), jobs=2)
    assert rep.searched == 2
    # workers' counters folded back + the parent's memory faulting
    assert tr.counters.get("cache.miss") == 2
    assert tr.counters.get("cache.store") == 2
    assert tr.counters.get("cache.hit") == 2    # parent replays artifacts
    assert store.resident("edgenext-reduced", 2)


def test_store_dedupes_grid_aliases(tmp_path):
    """'wl' at batch 2 and 'wl-b2' at batch 1 are one content key:
    warmed, searched, and stored exactly once."""
    store = ServeStore(tmp_path, HWSpec())
    with obs.tracing() as tr:
        rep = store.warm(["edgenext-reduced", "edgenext-reduced-b2"],
                         batches=(1, 2))
    assert len(rep.entries) == 3               # b1, b2, b4
    assert tr.counters.get("cache.store") == 3
    assert len(list(tmp_path.glob("*.json"))) == 3


# ---------------------------------------------------------------------------
# the batching policy
# ---------------------------------------------------------------------------


def _linear_points(lat1: float = 0.05):
    """Synthetic co-searched curve with latency linear in batch (what
    the compute-bound cost model actually produces)."""
    return [BatchPoint(workload=f"wl-b{b}", batch=b,
                       latency_s=lat1 * b, energy_j=1.0 * b,
                       edp=lat1 * b * b, key=f"k{b}")
            for b in (1, 4, 16, 64)]


def test_policy_non_degenerate_across_rates():
    pts = _linear_points()
    picks = rate_table(pts, (2.0, 15.0, 60.0),
                       dispatch_s=0.020, devices=4)
    assert [p.point.batch for p in picks] == [1, 4, 16]
    assert distinct_batches(picks) >= 2
    # every pick's throughput ceiling covers its arrival rate
    assert all(not p.saturated for p in picks)
    assert all(p.sustained_rps >= p.rate_rps for p in picks)


def test_policy_shards_over_cosearched_levels_only():
    pts = _linear_points()
    pick = pick_batch(pts, 60.0, dispatch_s=0.020, devices=4)
    # batch 16 served as 4 data-parallel shards of the searched b4
    assert (pick.point.batch, pick.devices) == (16, 4)
    assert pick.shard_point.batch == 4
    # devices=3 cannot shard any level (no co-searched batch/3): the
    # fan-out degrades to 1, never a scaled guess
    pick3 = pick_batch(pts, 10.0, dispatch_s=0.020, devices=3)
    assert pick3.devices == 1
    assert pick3.shard_point == pick3.point


def test_policy_single_device_low_rate_prefers_small_batch():
    pts = _linear_points()
    pick = pick_batch(pts, 0.5, dispatch_s=0.001, devices=1)
    assert pick.point.batch == 1


def test_policy_saturated_falls_back_to_max_throughput():
    pts = _linear_points()
    pick = pick_batch(pts, 1e6, dispatch_s=0.020, devices=1)
    assert pick.saturated
    best = max(pts, key=lambda p: p.batch / (0.020 + p.latency_s))
    assert pick.point.batch == best.batch
    with pytest.raises(ValueError):
        pick_batch([], 1.0)


# ---------------------------------------------------------------------------
# data-parallel fan-out + serving CLI
# ---------------------------------------------------------------------------


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV,
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_data_parallel_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.runtime.pipeline import data_parallel

        mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
        def fn(params, x):
            return jnp.tanh(x @ params["w"] + params["b"])

        k0, k1 = jax.random.split(jax.random.PRNGKey(0))
        params = {"w": jax.random.normal(k0, (8, 8)),
                  "b": jnp.ones((8,))}
        x = jax.random.normal(k1, (16, 8))
        dp = data_parallel(fn, mesh=mesh)
        assert jnp.allclose(dp(params, x), fn(params, x), atol=1e-6)
        try:
            dp(params, x[:6])
        except ValueError as e:
            assert "not divisible" in str(e)
        else:
            raise AssertionError("indivisible batch accepted")
        print("DPOK", jax.device_count())
    """)
    assert "DPOK 4" in out


@pytest.mark.slow
def test_serve_cli_warm_then_hit(tmp_path):
    """End-to-end: warm in one process, serve the lookup from another —
    the request replays the artifact (cache.hit) and never re-searches
    (cache.miss stays 0)."""
    base = ["--arch", "edgenext-reduced", "--batches", "1,2",
            "--cache-dir", str(tmp_path)]
    run = lambda extra: subprocess.run(
        [sys.executable, "-m", "repro.serve"] + base + extra,
        capture_output=True, text=True, env=ENV, cwd="/root/repo",
        timeout=600)
    warm = run(["--warm"])
    assert warm.returncode == 0, warm.stderr[-3000:]
    assert "serve.warm.cache.store,2," in warm.stdout
    look = run(["--lookup", "2", "--rates", "2,60", "--devices", "2"])
    assert look.returncode == 0, look.stderr[-3000:]
    assert "serve.cache.hit,1," in look.stdout
    assert "serve.cache.miss,0," in look.stdout
    assert "serve.policy.edgenext-reduced.distinct_batches," \
        in look.stdout
