"""The simulated request loop: policy boundary cases, the queueing
core against hand traces, and the ``(b-1)/(2λ)`` fill-wait closed form
against measured Poisson arrivals."""
import pytest

from repro import obs
from repro.serve import (BatchPoint, ServePolicy, model_fill_wait,
                         pick_batch, poisson_arrivals, run_loop,
                         simulate, trace_arrivals)
from repro.serve.loop import LOOP_RATES


def _pt(batch, lat):
    return BatchPoint(workload=f"w-b{batch}", batch=batch,
                      latency_s=lat, energy_j=1.0, edp=lat, key="k")


_CURVE = [_pt(1, 0.010), _pt(4, 0.036), _pt(16, 0.120)]


# ---------------------------------------------------------------------------
# policy boundary cases (satellite: defined, not incidental)
# ---------------------------------------------------------------------------


def test_policy_zero_rate_picks_batch_one():
    """λ=0: the fill form divides by zero; defined as batch 1 (nothing
    larger ever fills when nothing arrives)."""
    pick = pick_batch(_CURVE, 0.0)
    assert pick.point.batch == 1
    assert not pick.saturated
    # negative rates take the same defined path
    assert pick_batch(_CURVE, -1.0).point.batch == 1


def test_policy_zero_rate_marks_larger_batches_infeasible():
    pol = ServePolicy(dispatch_s=0.020)
    cands = {c.point.batch: c for c in pol.evaluate(_CURVE, 0.0)}
    assert not cands[1].saturated
    assert cands[1].expected_latency_s == pytest.approx(0.030)
    for b in (4, 16):
        assert cands[b].saturated
        assert cands[b].expected_latency_s == float("inf")


def test_policy_zero_rate_without_batch_one_point():
    """No co-searched batch-1 level: still the smallest level, never
    the max-throughput saturation fallback."""
    assert pick_batch(_CURVE[1:], 0.0).point.batch == 4


def test_policy_rate_at_exact_ceiling_is_feasible():
    """λ exactly equal to a level's sustained ceiling: the level still
    covers the rate (strict <), not a silent saturation fallback."""
    pol = ServePolicy(dispatch_s=0.020)
    # batch 1: sustained = 1 / (0.020 + 0.010)
    ceiling = 1.0 / 0.030
    cands = {c.point.batch: c for c in pol.evaluate(_CURVE, ceiling)}
    assert cands[1].sustained_rps == pytest.approx(ceiling)
    assert not cands[1].saturated
    # one epsilon above the ceiling saturates it
    above = {c.point.batch: c
             for c in pol.evaluate(_CURVE, ceiling * (1 + 1e-9))}
    assert above[1].saturated


# ---------------------------------------------------------------------------
# arrival generators
# ---------------------------------------------------------------------------


def test_poisson_arrivals_deterministic_and_calibrated():
    a = poisson_arrivals(4000, 15.0, seed=3)
    b = poisson_arrivals(4000, 15.0, seed=3)
    assert a == b                                  # seed-deterministic
    assert a != poisson_arrivals(4000, 15.0, seed=4)
    assert all(x < y for x, y in zip(a, a[1:]))    # strictly increasing
    mean_gap = a[-1] / len(a)
    assert mean_gap == pytest.approx(1 / 15.0, rel=0.05)
    with pytest.raises(ValueError):
        poisson_arrivals(10, 0.0)


def test_trace_arrivals_accumulates():
    assert trace_arrivals([0.5, 0.25, 0.25]) == [0.5, 0.75, 1.0]


def test_model_fill_wait_closed_form():
    assert model_fill_wait(1, 15.0) == 0.0
    assert model_fill_wait(4, 2.0) == pytest.approx(0.75)
    assert model_fill_wait(4, 0.0) == float("inf")


# ---------------------------------------------------------------------------
# the queueing core, pinned on hand-computed traces
# ---------------------------------------------------------------------------


def test_simulate_hand_trace_fill_and_queue():
    """Arrivals at 1,2,3,4s, batch 2, 0.5s service: batch A dispatches
    at t=2 (fills), B at t=4; the first member of each waits one gap."""
    rep = simulate([1.0, 2.0, 3.0, 4.0], batch=2, service_s=0.5,
                   rate_rps=1.0)
    assert rep.batches == 2 and rep.partial_batches == 0
    waits = [r.fill_wait_s for r in rep.records]
    assert waits == [1.0, 0.0, 1.0, 0.0]
    assert rep.fill_wait_mean_s == pytest.approx(0.5)
    assert rep.model_fill_wait_s == pytest.approx(0.5)   # (2-1)/(2*1)
    assert rep.fillwait_err == pytest.approx(0.0)
    # server free at 2.5 before batch B dispatches at 4: no queueing
    assert rep.queue_wait_mean_s == 0.0
    assert rep.makespan_s == pytest.approx(4.5)


def test_simulate_queueing_behind_busy_server():
    """Service longer than the batch gap: batch B queues behind A."""
    rep = simulate([1.0, 2.0, 3.0, 4.0], batch=2, service_s=3.0,
                   rate_rps=1.0)
    b = rep.records[2]                    # first member of batch B
    assert b.dispatched_s == pytest.approx(4.0)
    assert b.started_s == pytest.approx(5.0)      # A holds until 2+3
    assert b.queue_wait_s == pytest.approx(1.0)


def test_simulate_fill_timer_flushes_partials():
    """One arrival then silence: the fill timer dispatches a partial
    batch at first_arrival + timeout, and partials never enter the
    fill-wait mean (they wait the timer, not the fill)."""
    rep = simulate([1.0, 10.0], batch=4, service_s=0.1,
                   fill_timeout_s=2.0, rate_rps=1.0)
    assert rep.batches == 2 and rep.partial_batches == 2
    assert rep.records[0].dispatched_s == pytest.approx(3.0)
    assert not rep.records[0].full
    assert rep.records[1].dispatched_s == pytest.approx(12.0)
    assert rep.fill_wait_mean_s == 0.0     # no full batches to average
    assert rep.deadline_misses == 0


def test_simulate_end_of_stream_flush_without_timer():
    """No timer: the tail partial flushes at its last member's arrival
    (the simulation must terminate, not wait forever)."""
    rep = simulate([1.0, 2.0, 3.0], batch=2, service_s=0.1,
                   rate_rps=1.0)
    assert rep.batches == 2 and rep.partial_batches == 1
    assert rep.records[2].dispatched_s == pytest.approx(3.0)


def test_simulate_deadline_misses_counted_requests_still_served():
    rep = simulate([1.0, 1.1], batch=2, service_s=5.0, deadline_s=1.0,
                   rate_rps=10.0)
    assert rep.deadline_misses == 2
    assert rep.requests == 2               # served late, never dropped
    assert all(r.deadline_miss for r in rep.records)


def test_simulate_batch_one_is_exact():
    """b=1: every batch fills on arrival — measured 0, model 0, err 0."""
    rep = simulate(poisson_arrivals(500, 15.0, seed=0), batch=1,
                   service_s=0.001, rate_rps=15.0)
    assert rep.fill_wait_mean_s == 0.0
    assert rep.model_fill_wait_s == 0.0
    assert rep.fillwait_err == 0.0
    assert rep.partial_batches == 0


def test_simulate_rejects_bad_batch():
    with pytest.raises(ValueError):
        simulate([1.0], batch=0, service_s=0.1)


# ---------------------------------------------------------------------------
# the closed form vs sampled arrivals (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_fillwait_matches_closed_form_within_tolerance():
    """At 2000 Poisson arrivals the measured mean fill wait of full
    batches lands within 10% of (b-1)/(2λ) — the BENCH acceptance, at
    its exact rates."""
    for rate in LOOP_RATES:
        for batch in (4, 16):
            rep = simulate(poisson_arrivals(2000, rate, seed=7),
                           batch=batch, service_s=1e-4, rate_rps=rate)
            assert rep.fillwait_err < 0.10, \
                f"b={batch} λ={rate}: err {rep.fillwait_err:.3f}"


@pytest.mark.slow
def test_fillwait_convergence_sweep():
    """The wide sweep: every (batch, rate, seed) combo converges."""
    for seed in range(5):
        for rate in LOOP_RATES:
            for batch in (2, 4, 16, 64):
                rep = simulate(poisson_arrivals(4000, rate, seed=seed),
                               batch=batch, service_s=1e-4,
                               rate_rps=rate)
                assert rep.fillwait_err < 0.10


# ---------------------------------------------------------------------------
# run_loop: the store-driven end-to-end driver
# ---------------------------------------------------------------------------


def test_run_loop_end_to_end(tmp_path):
    from repro.serve import ServeStore
    store = ServeStore(tmp_path / "cache")
    with obs.tracing() as tr:
        rep = run_loop(store, "edgenext-reduced", rate_rps=30.0,
                       n_requests=600, seed=1, batch=4, batches=(1, 4),
                       dispatch_s=0.001)
    assert rep.batch == 4 and rep.requests == 600
    assert rep.fillwait_err < 0.10
    assert tr.counters["serve.loop.requests"] == 600
    assert tr.counters["serve.loop.batches"] == rep.batches
    assert tr.gauges["serve.loop.fillwait_err"] == rep.fillwait_err
    # the driver co-searched the curve through the serving ladder
    assert tr.counters["cache.miss"] == 2
    # same store, same seed: a second run replays and reproduces
    rep2 = run_loop(store, "edgenext-reduced", rate_rps=30.0,
                    n_requests=600, seed=1, batch=4, batches=(1, 4),
                    dispatch_s=0.001)
    assert rep2.fill_wait_mean_s == rep.fill_wait_mean_s


def test_run_loop_policy_pick_and_deadlines(tmp_path):
    """Without an explicit batch the policy picks; tiny service + low
    rate => batch 1 (fill wait dominates), and a generous deadline is
    never missed."""
    from repro.serve import ServeStore
    store = ServeStore(tmp_path / "cache")
    rep = run_loop(store, "edgenext-reduced", rate_rps=2.0,
                   n_requests=200, seed=0, batches=(1, 4),
                   dispatch_s=0.001, deadline_s=10.0)
    assert rep.batch == 1
    assert rep.deadline_misses == 0
    assert rep.fillwait_err == 0.0
