"""Factored spatial mappings: legality, cycle math, ablation pins.

The load-bearing claims of the factored mapspace:
  * cycles/legality: ``cycles_factored`` is the plain ceil product over
    per-dim unroll factors, reduction wiring is legal per axis segment
    (reduction dim innermost, one per axis, never split across axes),
    and the fixed-wiring column tree voids non-reduction col factors;
  * degenerate mappings never raise: every layer of all 9 registered
    workloads yields a non-empty, non-raising mapping set, and a
    mapping dim the layer does not carry is a no-op, not an error;
  * the factored space never loses to the pair space (ties keep the
    pair) and strictly wins on the depthwise/small-dim layers — mean
    spatial utilization improves;
  * equivalence pin: ``spatial_mode="pair"`` reproduces the
    SEARCH_VERSION=4 search bit for bit (dedup on AND off) — the pair
    golden snapshot is byte-identical to the retired v4 golden except
    for the version field.
"""
import dataclasses
import json
from pathlib import Path

import pytest

from repro.configs.edgenext_s import CONFIG
from repro.core import dataflow
from repro.core.costmodel import HWSpec
from repro.core.workload import MAC_OPS, SCAN, Layer, edgenext_workload
from repro.search import (WORKLOADS, auto_schedule, evaluate_schedule,
                          get_workload, load_schedule, save_schedule,
                          schedule_key)
from repro.search import mapper
from repro.search.memo import SearchMemo

HW = HWSpec()
WL = edgenext_workload(CONFIG)


# ---------------------------------------------------------------------------
# factored cycle math + wiring legality
# ---------------------------------------------------------------------------


def test_factored_cycles_is_ceil_product():
    """4xOX * 4xK on rows, 16xC on cols: every dim's unroll is the
    product of its factors across axes; unmapped dims run temporally."""
    l = Layer("l", "pwconv", k=24, c=40, ox=20, oy=3)
    fm = ((("ox", 4), ("k", 4)), (("c", 16),))
    want = 5 * 6 * -(-40 // 16) * 3          # ox/4, k/4, c/16, oy temporal
    assert dataflow.cycles_factored(l, fm) == want
    # a dim on both axes multiplies its factors (4x4 of OX)
    fm2 = ((("ox", 4),), (("ox", 4),))
    assert dataflow.cycles_factored(l, fm2) == -(-20 // 16) * 24 * 40 * 3
    # pair-degenerate factored form == the pair cycles
    assert dataflow.cycles_factored(l, ((("ox", 16),), (("c", 16),))) \
        == dataflow.cycles_generic(l, ("ox", "c"))


def test_factored_dispatch_through_cycles():
    l = Layer("l", "pwconv", k=24, c=40, ox=20)
    fm = ((("ox", 4), ("k", 4)), (("c", 16),))
    assert dataflow.cycles(l, fm) == dataflow.cycles_factored(l, fm)
    assert dataflow.is_factored(fm)
    assert not dataflow.is_factored(("ox", "c"))
    assert not dataflow.is_factored("OXC")
    assert dataflow.mapping_label(fm) == "4xOX*4xK|16xC"
    assert dataflow.mapping_label(("ox", "c")) == "OX|C"


def test_factored_legality_per_axis_segment():
    l = Layer("l", "pwconv", k=24, c=40, ox=20, fx=3)
    red = dataflow.reduction_dims(l)
    assert "c" in red and "fx" in red
    # reduction dim must be the innermost (last) factor of its axis
    assert dataflow.factored_legal(l, ((("ox", 4), ("c", 4)), (("k", 16),)))
    assert not dataflow.factored_legal(
        l, ((("c", 4), ("ox", 4)), (("k", 16),)))
    # at most one reduction dim per axis
    assert not dataflow.factored_legal(
        l, ((("fx", 3), ("c", 4)), (("k", 16),)))
    # a reduction dim never splits across both axes
    assert not dataflow.factored_legal(l, ((("c", 4),), (("c", 4),)))
    # factor product must fit the axis
    assert not dataflow.factored_legal(l, ((("ox", 8), ("k", 4)), ()))
    with pytest.raises(ValueError):
        dataflow.cycles_factored(l, ((("c", 4), ("ox", 4)), (("k", 16),)))


def test_factored_fixed_wiring_voids_nonreduction_col_segments():
    """The hard-wired column adder tree: non-reduction column factors
    are void (the dim runs temporally), reduction factors still bite —
    the factored generalization of the pair rule."""
    l = Layer("l", "pwconv", k=24, c=40, ox=20)
    fm = ((("ox", 16),), (("k", 4), ("c", 4)))
    got = dataflow.cycles_factored(l, fm, fixed_wiring=True)
    assert got == -(-20 // 16) * 24 * -(-40 // 4)     # k void, c kept
    assert dataflow.cycles_factored(l, fm) == \
        -(-20 // 16) * -(-24 // 4) * -(-40 // 4)


def test_spatial_utilization_generalizes_to_factored():
    l = Layer("l", "matmul", b=4, k=12, c=784, ox=12)
    pair = mapper.best_mapping(l, spatial_mode="pair")
    fac = mapper.best_mapping(l, spatial_mode="factored")
    assert fac.utilization >= pair.utilization
    assert fac.utilization == pytest.approx(
        dataflow.spatial_utilization(l, fac.mapping))


# ---------------------------------------------------------------------------
# degenerate mappings never raise (all 9 workloads)
# ---------------------------------------------------------------------------


def test_cycles_generic_tolerates_absent_dims():
    """A mapping dim the layer does not carry is a degenerate (no-op)
    unrolling, not an error — only row == col is rejected."""
    l = Layer("l", "pwconv", k=24, c=40, ox=20)
    base = dataflow.cycles_generic(l, ("ox", "c"))
    assert dataflow.cycles_generic(l, ("ox", "z")) == \
        -(-20 // 16) * 24 * 40
    assert dataflow.cycles_generic(l, ("z", "q")) == 24 * 40 * 20
    assert base == -(-20 // 16) * 24 * -(-40 // 16)
    with pytest.raises(ValueError):
        dataflow.cycles_generic(l, ("ox", "ox"))


@pytest.mark.parametrize("name", WORKLOADS)
def test_every_layer_has_nonempty_nonraising_mappings(name):
    """Satellite proof: every layer of every registered workload yields
    a non-empty mapping set, none of whose members raise, and
    best_mapping succeeds in both spatial modes for every MAC layer."""
    memo = SearchMemo()
    for l in get_workload(name):
        ms = list(mapper.enumerate_mappings(l))
        assert ms, l.name
        sizes = dataflow.dim_sizes(l)
        useful = [d for d in dataflow.SPATIAL_DIMS if sizes[d] > 1]
        for m in ms:
            dataflow.cycles_generic(l, m)          # must not raise
            dataflow.cycles_generic(l, m, fixed_wiring=True)
            if len(useful) >= 2:
                # size-1 dims never consume enumeration slots
                assert sizes[m[0]] > 1 and sizes[m[1]] > 1, (l.name, m)
        if l.op in MAC_OPS:
            for mode in ("pair", "factored"):
                mc = mapper.best_mapping(l, HW.rows, HW.cols,
                                         spatial_mode=mode, memo=memo)
                assert mc.cycles * HW.rows * HW.cols >= l.macs
                assert 0 < mc.utilization <= 1.0


def test_fully_degenerate_layer_still_maps():
    l = Layer("one", "pwconv")                     # every dim extent 1
    assert list(mapper.enumerate_mappings(l))
    mc = mapper.best_mapping(l)
    assert mc.cycles == 1


# ---------------------------------------------------------------------------
# factored never loses; strictly wins on depthwise/small-dim layers
# ---------------------------------------------------------------------------


def test_factored_never_loses_ties_keep_pair():
    memo = SearchMemo()
    strict = 0
    for l in WL:
        if l.op not in MAC_OPS:
            continue
        pair = mapper.best_mapping(l, spatial_mode="pair", memo=memo)
        fac = mapper.best_mapping(l, spatial_mode="factored", memo=memo)
        assert fac.cycles <= pair.cycles, l.name
        if fac.cycles == pair.cycles:
            # a degenerate factored search IS the pair search
            assert fac.mapping == pair.mapping, l.name
        else:
            strict += 1
            assert dataflow.cycles_factored(l, fac.mapping, HW.rows,
                                            HW.cols) == fac.cycles
            assert dataflow.factored_legal(l, fac.mapping, HW.rows,
                                           HW.cols)
    assert strict > 0, "EdgeNeXt-S must have factored winners"


def test_factored_schedule_beats_pair_on_edgenext():
    """The acceptance criterion, as a test: factored EDP < pair EDP on
    the depthwise-heavy EdgeNeXt-S, with higher mean utilization, and
    the two modes hash to distinct schedule keys."""
    fac = auto_schedule(WL, HW, workload="edgenext-s")
    pair = auto_schedule(WL, HW, workload="edgenext-s",
                         spatial_mode="pair")
    assert fac.cost["edp"] < pair.cost["edp"]
    assert fac.cost["spatial_util"] > pair.cost["spatial_util"]
    assert fac.key != pair.key
    assert fac.spatial_mode == "factored" and pair.spatial_mode == "pair"
    assert any(dataflow.is_factored(m) for m in fac.mappings.values())
    assert not any(dataflow.is_factored(m) for m in pair.mappings.values())
    # evaluation replays the factored mappings consistently
    nc = evaluate_schedule(WL, fac, HW)
    assert nc.edp == pytest.approx(fac.cost["edp"])


def test_unknown_spatial_mode_rejected():
    with pytest.raises(ValueError):
        mapper.best_mapping(WL[0] if WL[0].op in MAC_OPS else
                            next(l for l in WL if l.op in MAC_OPS),
                            spatial_mode="diagonal")


# ---------------------------------------------------------------------------
# equivalence pin: spatial_mode="pair" == the SEARCH_VERSION=4 search
# ---------------------------------------------------------------------------


def _v4_best_pair(layer):
    """The retired v4 selection rule, reimplemented verbatim: min
    (cycles, mapping) over the ordered-pair enumeration."""
    best = None
    for m in mapper.enumerate_mappings(layer):
        cyc = dataflow.cycles_generic(layer, m, HW.rows, HW.cols)
        if best is None or (cyc, m) < best:
            best = (cyc, m)
    return best[1]


@pytest.mark.parametrize("name", WORKLOADS)
def test_pair_mode_bit_identical_to_v4_selection(name):
    """On every registered workload: pair-mode dedup-on and dedup-off
    schedules are bit-identical, and every layer's mapping equals the
    v4 argmin — the pre-factored search survives as the ablation."""
    wl = get_workload(name)
    fast = auto_schedule(wl, HW, workload=name, spatial_mode="pair",
                         dedup=True)
    brute = auto_schedule(wl, HW, workload=name, spatial_mode="pair",
                          dedup=False)
    assert dataclasses.asdict(fast) == dataclasses.asdict(brute)
    by_name = {l.name: l for l in wl}
    for lname, m in fast.mappings.items():
        if by_name[lname].op == SCAN:
            # scan layers postdate v4: their mapping comes from the
            # carry-constrained scan enumerator (ox is never spatial),
            # while the v4 argmin happily splits ox
            continue
        assert m == _v4_best_pair(by_name[lname]), lname


def test_pair_golden_matches_v4_snapshot():
    """The pair-mode EdgeNeXt-S schedule must reproduce the pair golden
    snapshot — which is byte-identical to the retired SEARCH_VERSION=4
    golden except for its version field (checked at generation time).
    Regenerate after intentional cost-model changes with:
      PYTHONPATH=src python -m repro.search --workload edgenext-s \
          --spatial-mode pair \
          --golden tests/golden/edgenext_s_schedule_pair.json
    """
    p = Path(__file__).parent / "golden" / "edgenext_s_schedule_pair.json"
    gold = json.loads(p.read_text())
    sched = auto_schedule(WL, HW, workload="edgenext-s",
                          spatial_mode="pair")
    assert gold["version"] == sched.version
    assert [list(g) for g in sched.groups] == gold["groups"]
    assert sched.tiles == gold["tiles"]
    assert sched.cost["edp"] == pytest.approx(gold["cost"]["edp"])
    assert sched.cost["edp_tiled"] == \
        pytest.approx(gold["cost"]["edp_tiled"])


def test_spatial_mode_is_a_search_dimension():
    assert schedule_key(WL, HW) == schedule_key(WL, HW, "full", "factored")
    assert schedule_key(WL, HW, "full", "pair") != schedule_key(WL, HW)


# ---------------------------------------------------------------------------
# serialization round-trip with factored mappings
# ---------------------------------------------------------------------------


def test_factored_schedule_json_roundtrip(tmp_path):
    sched = auto_schedule(WL, HW, workload="edgenext-s")
    assert any(dataflow.is_factored(m) for m in sched.mappings.values())
    p = tmp_path / "sched.json"
    save_schedule(sched, p)
    back = load_schedule(p)
    assert back is not None
    assert back.key == sched.key
    assert back.spatial_mode == "factored"
    assert back.mappings == sched.mappings     # tuples, not JSON lists
    nc = evaluate_schedule(WL, back, HW)
    assert nc.edp == pytest.approx(sched.cost["edp"])


def test_as_mapping_canonicalizes_json_forms():
    assert dataflow.as_mapping("OXC") == "OXC"
    assert dataflow.as_mapping(["ox", "c"]) == ("ox", "c")
    assert dataflow.as_mapping([[["ox", 4], ["k", 4]], [["c", 16]]]) == \
        ((("ox", 4), ("k", 4)), (("c", 16),))
