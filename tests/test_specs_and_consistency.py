"""Input/cache spec structure for every dry-run cell + decode-vs-forward
consistency for the stateful families (hybrid, enc-dec)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config, reduced
from repro.launch.specs import cache_specs, input_specs
from repro.models import get_module, params as P


def _cells():
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape


@pytest.mark.parametrize("arch,shape",
                         list(_cells()),
                         ids=lambda v: getattr(v, "name", v))
def test_input_specs_structure(arch, shape):
    cfg = get_config(arch)
    batch = input_specs(cfg, shape)
    B = shape.global_batch
    if shape.kind == "train":
        assert batch["labels"].shape == (B, shape.seq_len)
        if cfg.embedding_inputs or cfg.family == "audio":
            assert batch["inputs_embeds"].shape[0] == B
            assert batch["inputs_embeds"].shape[2] == cfg.d_model
        else:
            assert batch["tokens"].shape == (B, shape.seq_len)
    elif shape.kind == "decode":
        assert batch["tokens"].shape == (B, 1)
        cache = cache_specs(cfg, shape)
        leaves = jax.tree.leaves(cache)
        assert leaves, arch
        # no cache leaf may exceed one v5e HBM when sharded 256 ways
        total = sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves)
        assert total / 256 < 16e9, f"{arch} cache {total/1e9:.1f}GB global"
    if cfg.rope == "mrope" and shape.kind != "decode":
        assert batch["positions"].shape[0] == 3


def test_total_cell_count_matches_design():
    """DESIGN.md: 33 live cells (40 nominal - 7 documented long_500k
    skips for full-attention archs)."""
    cells = list(_cells())
    assert len(cells) == 33
    longs = [a for a, s in cells if s.name == "long_500k"]
    assert sorted(longs) == ["h2o-danube-1.8b", "recurrentgemma-2b",
                             "rwkv6-1.6b"]


@pytest.mark.slow
def test_decode_matches_forward_recurrentgemma():
    """RG: associative-scan prefill == stepwise decode (state handoff)."""
    cfg = reduced(get_config("recurrentgemma-2b"))
    mod = get_module(cfg)
    params = P.init_params(jax.random.PRNGKey(0), mod.param_defs(cfg))
    T = 12
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, T), 0,
                                cfg.vocab_size)
    hidden, _ = mod.forward(cfg, params, {"tokens": tokens}, remat=False,
                            use_flash=False)
    full_logits = mod.logits_fn(cfg, params, hidden)
    prefix = 6
    _, cache = mod.prefill(cfg, params, {"tokens": tokens[:, :prefix]},
                           use_flash=False)
    # grow attention caches to T (they were prefix-sized)
    cache = cache._replace(
        attn_k=[jnp.pad(k, ((0, 0), (0, 0), (0, T - k.shape[2]), (0, 0)))
                for k in cache.attn_k],
        attn_v=[jnp.pad(v, ((0, 0), (0, 0), (0, T - v.shape[2]), (0, 0)))
                for v in cache.attn_v])
    for t in range(prefix, T):
        logits, cache = mod.decode_step(cfg, params, cache,
                                        {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(logits[0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=3e-3, atol=3e-3)


@pytest.mark.slow
def test_decode_matches_forward_seamless():
    """Enc-dec: teacher-forced decoder == stepwise decode vs the same
    encoder memory."""
    cfg = reduced(get_config("seamless-m4t-large-v2"))
    mod = get_module(cfg)
    params = P.init_params(jax.random.PRNGKey(0), mod.param_defs(cfg))
    B, S_src, T = 1, 10, 8
    embeds = jax.random.normal(jax.random.PRNGKey(1), (B, S_src,
                                                       cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                cfg.vocab_size)
    hidden, _ = mod.forward(cfg, params,
                            {"inputs_embeds": embeds, "tokens": tokens},
                            remat=False, use_flash=False)
    full_logits = mod.logits_fn(cfg, params, hidden)
    _, cache = mod.prefill(cfg, params,
                           {"inputs_embeds": embeds,
                            "tokens": tokens[:, :1]},
                           use_flash=False, decode_len=T)
    for t in range(1, T):
        logits, cache = mod.decode_step(cfg, params, cache,
                                        {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(logits[0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=3e-3, atol=3e-3)


def test_xca_rows_stochastic():
    """EdgeNeXt XCA: channel-attention rows sum to 1 (softmax property) —
    attention over a constant V returns the constant."""
    from repro.configs.edgenext_s import reduced_edgenext
    from repro.models import edgenext
    cfg = reduced_edgenext()
    params = P.init_params(jax.random.PRNGKey(0),
                           edgenext.param_defs(cfg))
    bp = params["stages"][1]["sdta_blocks"][0]
    # force identity-ish qkv so v is controlled: use the real block but
    # check finiteness + shape here, stochasticity via the proj-free path
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.dims[1]))
    out = edgenext.xca(bp, x, cfg.heads)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
