"""Substrate tests: checkpoint store, data pipeline, optimizer,
compression, watchdog, HLO parser, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.configs import ARCHS, get_config
from repro.core import hloanalysis
from repro.data.synthetic import SyntheticLMDataset
from repro.models import get_module, params as param_lib
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         global_norm, warmup_cosine)
from repro.optim.compression import (dequantize_int8, quantize_int8,
                                     quantize_with_feedback)
from repro.runtime.watchdog import StragglerWatchdog


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree(key):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (4, 8)),
            "nested": {"b": jax.random.normal(ks[1], (3,)),
                       "c": [jnp.ones((2, 2)), jnp.zeros((5,))]},
            "count": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(tmp_path, 3, tree)
    step, restored = load_checkpoint(tmp_path, like=tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_gc(tmp_path, key):
    tree = _tree(key)
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_atomicity(tmp_path, key):
    """A leftover .tmp dir must never shadow a committed checkpoint."""
    tree = _tree(key)
    save_checkpoint(tmp_path, 1, tree)
    (tmp_path / "step_00000002.tmp").mkdir()      # simulated crash
    assert latest_step(tmp_path) == 1
    step, _ = load_checkpoint(tmp_path, like=tree)
    assert step == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_resume():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4)
    b1 = ds.batch(10)
    ds2 = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4)
    b2 = ds2.batch(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_labels_are_next_tokens():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4,
                            noise=0.0)
    b = ds.batch(0)
    # bigram language: label = perm[token] everywhere when noise=0
    np.testing.assert_array_equal(b["labels"], ds.perm[b["tokens"]])


def test_data_process_sharding_differs():
    kw = dict(vocab_size=128, seq_len=16, global_batch=8, process_count=2)
    d0 = SyntheticLMDataset(process_index=0, **kw)
    d1 = SyntheticLMDataset(process_index=1, **kw)
    assert d0.local_batch == 4
    assert not np.array_equal(d0.batch(0)["tokens"], d1.batch(0)["tokens"])


def test_data_steps_differ():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic(key):
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros((8,))}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, lr=0.05,
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm(key):
    g = {"a": jax.random.normal(key, (32,)) * 100}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_warmup_cosine_shape():
    fn = warmup_cosine(1e-3, 10, 100)
    lrs = [float(fn(s)) for s in range(100)]
    assert lrs[0] > 0                       # no wasted step-0
    assert abs(max(lrs) - 1e-3) < 1e-9
    assert np.argmax(lrs) == 9              # peak at end of warmup
    assert lrs[-1] < 0.2 * 1e-3             # decayed


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_error_bound(key):
    x = jax.random.normal(key, (1024,)) * 3
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_unbiased(key):
    """With feedback, the accumulated dequantized sum tracks the true sum
    (compression error does not accumulate)."""
    xs = jax.random.normal(key, (50, 256))
    residual = jnp.zeros((256,))
    acc = jnp.zeros((256,))
    for i in range(50):
        q, scale, residual = quantize_with_feedback(xs[i], residual)
        acc = acc + dequantize_int8(q, scale)
    true = xs.sum(0)
    # residual bounds the total error
    np.testing.assert_allclose(np.asarray(acc + residual), np.asarray(true),
                               rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(acc - true).max()) < 0.2


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_flags_outliers():
    events = []
    wd = StragglerWatchdog(threshold=2.0, patience=2, warmup_steps=0,
                           on_escalate=events.append)
    wd.record(0, 1.0)
    for s in range(1, 5):
        assert not wd.record(s, 1.0)
    assert wd.record(5, 5.0)
    assert wd.record(6, 5.0)
    assert events                       # escalated after patience=2


def test_watchdog_ignores_warmup():
    wd = StragglerWatchdog(warmup_steps=2, threshold=2.0)
    assert not wd.record(0, 100.0)      # compile step
    assert not wd.record(1, 100.0)


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------


HLO_SAMPLE = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[16,64]{1,0} %p0), dimensions={1}
  %ar = f32[256,128]{1,0} all-reduce(f32[256,128]{1,0} %x), to_apply=%add
  %rs = f32[16,8]{1,0} reduce-scatter(f32[16,128]{1,0} %y), dimensions={1}
  %done = bf16[4]{0} all-gather-done(bf16[4]{0} %h)
"""


def test_parse_collectives_counts_and_bytes():
    stats = hloanalysis.parse_collectives(HLO_SAMPLE)
    assert stats["all-gather"].count == 1
    assert stats["all-gather"].result_bytes == 16 * 1024 * 2
    assert stats["all-reduce"].result_bytes == 256 * 128 * 4
    # reduce-scatter wire bytes use the (bigger) operand
    assert stats["reduce-scatter"].wire_bytes("reduce-scatter") == \
        16 * 128 * 4
    # all-reduce wire = 2x
    assert stats["all-reduce"].wire_bytes("all-reduce") == 2 * 256 * 128 * 4


def test_roofline_terms():
    r = hloanalysis.Roofline(flops_per_device=197e12,
                             hbm_bytes_per_device=819e9 / 2,
                             collective_bytes_per_device=0.0)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.bound == "compute"
    assert r.roofline_fraction == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# sharding rules: every assigned arch divides the production mesh
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_pspecs_divisible_on_production_mesh(arch):
    cfg = get_config(arch)
    defs = get_module(cfg).param_defs(cfg)
    sizes = {"data": 16, "model": 16}
    rules = param_lib.resolve_rules(sizes, kv_heads=cfg.num_kv_heads,
                                    num_heads=cfg.num_heads)

    def check(d: param_lib.ParamDef):
        spec = param_lib._leaf_pspec(d, rules)
        for dim, ax in zip(d.shape, spec):
            if ax is not None and dim % sizes[ax] != 0:
                rules[[a for a in d.axes][list(spec).index(ax)]] = None

    # demote-then-validate mirrors runtime.model_param_pspecs
    param_lib.tree_map_defs(check, defs)
    param_lib.validate_pspecs(defs, rules, sizes)
