"""End-to-end system tests: train loop with crash-resume determinism,
serve round trip, loss actually decreases on the learnable synthetic
language."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import SHAPES_BY_NAME, get_config, reduced
from repro.data.synthetic import make_dataset
from repro.models import get_module, params as P
from repro.optim import adamw_init, warmup_cosine
from repro.runtime import build_train_step


def _run_steps(cfg, params, opt, ds, step_fn, start, end):
    for s in range(start, end):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        params, opt, metrics = step_fn(params, opt, batch)
    return params, opt, metrics


def test_resume_bitexact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical."""
    cfg = reduced(get_config("olmo-1b"))
    mod = get_module(cfg)
    shape = dataclasses.replace(SHAPES_BY_NAME["train_4k"], seq_len=32,
                                global_batch=4)
    ds = make_dataset(cfg, shape, seed=11)
    step_fn = jax.jit(build_train_step(
        cfg, lr_schedule=warmup_cosine(1e-3, 2, 10)))

    params0 = P.init_params(jax.random.PRNGKey(0), mod.param_defs(cfg))
    opt0 = adamw_init(params0)

    # straight run
    p_a, o_a, _ = _run_steps(cfg, params0, opt0, ds, step_fn, 0, 6)

    # interrupted run
    p_b, o_b, _ = _run_steps(cfg, params0, opt0, ds, step_fn, 0, 3)
    ck = AsyncCheckpointer(tmp_path)
    ck.save(3, {"params": p_b, "opt": o_b})
    ck.wait()
    step, restored = load_checkpoint(tmp_path,
                                     like={"params": p_b, "opt": o_b})
    assert step == 3
    p_c, o_c, _ = _run_steps(cfg, restored["params"], restored["opt"], ds,
                             step_fn, 3, 6)

    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o_a.count) == int(o_c.count) == 6


def test_loss_decreases_on_synthetic_language():
    """The bigram synthetic language is learnable: 60 steps should cut the
    loss substantially from its initial value."""
    cfg = reduced(get_config("h2o-danube-1.8b"))
    mod = get_module(cfg)
    shape = dataclasses.replace(SHAPES_BY_NAME["train_4k"], seq_len=64,
                                global_batch=8)
    ds = make_dataset(cfg, shape, seed=5)
    step_fn = jax.jit(build_train_step(
        cfg, lr_schedule=warmup_cosine(2e-3, 10, 60)))
    params = P.init_params(jax.random.PRNGKey(0), mod.param_defs(cfg))
    opt = adamw_init(params)
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5]), (
        losses[:5], losses[-10:])


@pytest.mark.slow
def test_train_cli_end_to_end(tmp_path):
    """The actual launcher binary: train, then resume."""
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "olmo-1b",
           "--reduced", "--steps", "8", "--batch", "2", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
           "--log-every", "4"]
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r1 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        cwd="/root/repo", timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert latest_step(tmp_path) == 8
    cmd[7] = "12"                       # --steps 12: resume 8 -> 12
    r2 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        cwd="/root/repo", timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 8" in r2.stdout
    assert latest_step(tmp_path) == 12


@pytest.mark.slow
def test_serve_cli_end_to_end():
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch",
           "qwen2-vl-2b", "--reduced", "--batch", "2", "--prompt-len",
           "16", "--gen", "4"]
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "generated" in r.stdout
