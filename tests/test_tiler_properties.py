"""Property-based invariants for the divisor/imperfect-factor tile search.

Hypothesis is unavailable offline, so the properties run as seeded
randomized sweeps: random pixel-aligned layer chains and buffer budgets,
checked against a brute-force reference that scores EVERY tile size
1..n under the same ragged-edge traffic model.  The invariants:

  * feasibility — every returned tile fits the local buffer; infeasible
    groups return None, never an over-budget tile;
  * coverage — the chosen (tile_x, tile_c) cover their extents exactly
    (sum of round sizes == extent, ragged last round included);
  * optimality sandwich — the tiler never beats the full exhaustive
    optimum (cost-model consistency) and never loses to the best
    divisor (the enumeration really contains all divisors);
  * the pow2-only ablation never beats the full enumeration.

Plus the ``search.lower._snap`` contract: (block, n_ragged) with the
degenerate ``lo > hi`` band collapsing to the upper bound.
"""
import random

import pytest

from repro.core import tiling
from repro.core.costmodel import HWSpec
from repro.core.tiling import Tiling, ceil_div, divisors
from repro.core.workload import ACT, NORM, PWCONV, Layer
from repro.search import lower, mapper, tiler


# ---------------------------------------------------------------------------
# core.tiling primitives
# ---------------------------------------------------------------------------


def test_divisors_exact():
    for n in (1, 2, 7, 48, 96, 160, 197, 304, 4096):
        ds = divisors(n)
        assert ds == sorted(d for d in range(1, n + 1) if n % d == 0)


def test_tiling_covers_and_ragged():
    rng = random.Random(1)
    for _ in range(200):
        n = rng.randint(1, 5000)
        t = rng.randint(1, n)
        ti = Tiling(n, t)
        sizes = ti.round_sizes()
        assert sum(sizes) == n                      # coverage, always
        assert len(sizes) == ti.rounds == ceil_div(n, t)
        assert ti.ragged == n % t
        assert all(s == t for s in sizes[:-1])
        assert sizes[-1] == (ti.ragged or t)


def test_tile_candidates_contains_divisors_and_extras():
    cands = tiling.tile_candidates(160, extra=(38, 999))
    assert set(divisors(160)) <= set(cands)
    assert 38 in cands and 160 in cands             # extras clamped to n
    assert all(1 <= c <= 160 for c in cands)
    assert tiling.tile_candidates(160, mode="pow2") == \
        [1, 2, 4, 8, 16, 32, 64, 128]
    # legacy = the PR-1 seed space: pow2s + the extent + the pivots,
    # but no non-trivial divisors
    legacy = tiling.tile_candidates(160, extra=(38,), mode="legacy")
    assert 160 in legacy and 38 in legacy and 5 not in legacy
    assert set(legacy) <= set(cands)                # full is a superset
    with pytest.raises(ValueError):
        tiling.tile_candidates(8, mode="bogus")


# ---------------------------------------------------------------------------
# brute-force reference (same ragged traffic model, every tile size)
# ---------------------------------------------------------------------------


def _pair_traffic(exp, proj, tx, local_buffer, full_width=False):
    """Mirror of fusion.optimize_tile's model for one tile_x candidate;
    None if infeasible."""
    n = exp.b * exp.ox * exp.oy
    c_mid = exp.k
    bits = exp.bits // 8
    tc = min(c_mid, local_buffer // max(1, tx * bits))
    if tc < 1 or tx * tc * bits > local_buffer:
        return None
    if full_width and tc < c_mid:
        return None
    n_xt = ceil_div(n, tx)
    n_ct = ceil_div(c_mid, tc)
    return (n * exp.c * bits * n_ct
            + (exp.c * c_mid + c_mid * proj.k) * bits * n_xt
            + n * proj.k * bits)


def _brute_optimum(exp, proj, local_buffer, tile_sizes, full_width=False):
    costs = [c for c in (_pair_traffic(exp, proj, tx, local_buffer,
                                       full_width)
                         for tx in tile_sizes) if c is not None]
    return min(costs) if costs else None


def _rand_pair(rng):
    n = rng.randint(1, 512)
    c_in = rng.randint(1, 96)
    c_mid = rng.randint(1, 512)
    c_out = rng.randint(1, 96)
    exp = Layer("e", PWCONV, k=c_mid, c=c_in, ox=n)
    proj = Layer("p", PWCONV, k=c_out, c=c_mid, ox=n)
    return exp, proj


def test_pair_tiler_optimality_sandwich():
    """full-exhaustive optimum <= tiler <= best-divisor optimum, and the
    returned tile always fits the budget and covers both extents."""
    rng = random.Random(42)
    for _ in range(40):
        exp, proj = _rand_pair(rng)
        n = exp.b * exp.ox * exp.oy
        buf = rng.choice((64, 512, 4096, 24 * 1024))
        t = tiler.optimize_tile(exp, proj, local_buffer=buf)
        exhaustive = _brute_optimum(exp, proj, buf, range(1, n + 1))
        if t is None:
            assert exhaustive is None, "tiler missed a feasible tile"
            continue
        assert t.buffer_bytes <= buf
        assert sum(Tiling(n, t.tile_x).round_sizes()) == n
        assert sum(Tiling(exp.k, t.tile_c).round_sizes()) == exp.k
        assert Tiling(n, t.tile_x).ragged == t.ragged_x
        assert Tiling(exp.k, t.tile_c).ragged == t.ragged_c
        assert t.sram_traffic >= exhaustive          # never beats brute force
        div_opt = _brute_optimum(exp, proj, buf, divisors(n))
        if div_opt is not None:
            assert t.sram_traffic <= div_opt         # contains all divisors


def test_pair_tiler_ablation_modes_never_beat_full():
    rng = random.Random(7)
    for _ in range(25):
        exp, proj = _rand_pair(rng)
        buf = rng.choice((256, 4096, 24 * 1024))
        full = tiler.optimize_tile(exp, proj, local_buffer=buf)
        for mode in ("legacy", "pow2"):
            abl = tiler.optimize_tile(exp, proj, local_buffer=buf,
                                      mode=mode)
            if abl is None:
                continue                # ablation space may miss entirely
            assert full is not None
            assert full.sram_traffic <= abl.sram_traffic


def _rand_chain(rng):
    """Random pixel-aligned pwconv chain with interleaved nonlinears."""
    n = rng.randint(1, 256)
    widths = [rng.randint(1, 128) for _ in range(rng.randint(3, 5))]
    layers = []
    for i, (c, k) in enumerate(zip(widths, widths[1:])):
        layers.append(Layer(f"m{i}", PWCONV, k=k, c=c, ox=n))
        if rng.random() < 0.5:
            op = rng.choice((ACT, NORM))
            layers.append(Layer(f"n{i}", op, c=k, ox=n))
    return layers


def test_group_tiler_feasibility_and_coverage():
    rng = random.Random(9)
    for _ in range(40):
        chain = _rand_chain(rng)
        buf = rng.choice((128, 1024, 8192, 24 * 1024))
        t = tiler.tile_group(chain, local_buffer=buf)
        if t is None:
            continue
        assert t.buffer_bytes <= buf, "over-budget tile returned"
        if t.tile_x:                     # multi-MAC depth-first group
            n = chain[0].b * chain[0].ox * chain[0].oy
            ti = Tiling(n, t.tile_x)
            assert sum(ti.round_sizes()) == n
            assert ti.rounds == t.weight_rereads
            assert ti.ragged == t.ragged_x


def test_group_tiler_infeasible_returns_none():
    a = Layer("a", PWCONV, k=512, c=512, ox=64)
    b = Layer("b", PWCONV, k=512, c=512, ox=64)
    c = Layer("c", PWCONV, k=512, c=512, ox=64)
    # 3-MAC chain needs a full-width (512+512) x-slab: 1 pixel > 1000 B
    assert tiler.tile_group([a, b, c], local_buffer=1000) is None
    assert tiler.tile_group([a, b, c], local_buffer=1 << 20) is not None


# ---------------------------------------------------------------------------
# mapper temporal budgets (same ragged accounting)
# ---------------------------------------------------------------------------


def test_temporal_tiles_respect_buffers_and_cover():
    hw = HWSpec()
    rng = random.Random(3)
    for _ in range(10):
        l = Layer("l", PWCONV, k=rng.randint(1, 512),
                  c=rng.randint(1, 512), ox=rng.randint(1, 304))
        n_x, n_k, n_c = mapper.macro_extents(l)
        bytes_per = max(1, l.bits // 8)
        for t in mapper.enumerate_temporal(l, hw):
            assert 4 * t.tile_x * t.tile_k <= hw.output_rf_bytes \
                or t.tile_k == n_k
            assert bytes_per * t.tile_x * t.tile_c <= hw.input_mem_bytes \
                or t.tile_c == n_c
            assert sum(Tiling(n_x, t.tile_x).round_sizes()) == n_x


def test_temporal_pow2_mode_never_beats_full():
    hw = HWSpec()
    l = Layer("l", PWCONV, k=304, c=160, ox=304)
    full = mapper.best_temporal(l, hw)
    p2 = mapper.best_temporal(l, hw, tile_mode="pow2")
    assert full.sram_bytes <= p2.sram_bytes


# ---------------------------------------------------------------------------
# search.lower._snap contract
# ---------------------------------------------------------------------------


def test_snap_returns_block_and_ragged():
    b, r = lower._snap(64, 8, 256, 4096)
    assert (b, r) == (64, 0)
    b, r = lower._snap(300, 8, 256, 304)       # imperfect: 304 = 256 + 48
    assert (b, r) == (256, 48)
    assert b * (ceil_div(304, b) - 1) + r == 304
    b, r = lower._snap(64, 8, 256, 48)         # clamped to extent
    assert (b, r) == (32, 16)
    b, r = lower._snap(5, 8, 256, 4096)        # lo floor applies
    assert (b, r) == (8, 0)


def test_snap_degenerate_band_collapses_to_hi():
    """lo > hi: the cap must win — the block never exceeds hi."""
    b, r = lower._snap(100, 64, 8, 4096)
    assert b <= 8 and (b & (b - 1)) == 0
    assert r == 4096 % b
    b, r = lower._snap(1, 64, 8, 5)            # and never the extent
    assert b <= 5 and r == 5 % b


def test_snap_never_signals_false_perfection():
    rng = random.Random(11)
    for _ in range(200):
        extent = rng.randint(1, 5000)
        v = rng.randint(1, 1024)
        b, r = lower._snap(v, 8, 256, extent)
        assert 1 <= b <= extent
        assert r == extent % b
        assert (r == 0) == (extent % b == 0)
